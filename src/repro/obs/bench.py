"""Longitudinal bench observability: stamped snapshots, history, bench-diff.

`benchmarks/run.py` targets used to overwrite their ``BENCH_*.json`` in
place, so the repo had perf *points* but no perf *trajectory*.  This module
adds the time axis (DESIGN.md §17):

* `stamp(doc)` — attach ``{schema_version, git_sha, timestamp, backend,
  jax_version}`` header fields to a bench document.  The timestamp is
  injected here, at the eager edge — never inside jitted code.
* `write_bench(doc, out_path)` — the one emission seam every bench target
  calls: stamps the doc, writes today's snapshot JSON exactly as before,
  and appends one record per (row, metric) to the append-only history
  store ``BENCH_history/<bench>.jsonl``.
* `diff(base, head)` / the ``bench-diff`` CLI — noise-aware comparison of
  two history files: median-of-k per identity key, a per-op relative bar
  plus an absolute floor (CPU timers jitter tens of µs; a 60% swing on a
  30 µs kernel is noise, on a 30 ms solve it is a regression), exit 0/1.
  CI runs it against a committed baseline as a job-failing gate.

History record schema (one JSON object per line, ``schema`` versioned):

    {"schema": 1, "bench": "core", "key": "bench=core backend=cpu ...",
     "metric": "us_per_round", "value_us": 123.4,
     "git_sha": "...", "timestamp": "...", "backend": "cpu",
     "jax_version": "...", "quick": true}

The identity ``key`` is the bench name plus every *configuration* scalar of
the row (op, storage, n, tile_size, engine, ...), sorted ``k=v`` — and it
includes ``backend`` and ``quick`` so a CPU-quick run never silently
compares against a TPU-full run.  *Outcome* fields (rounds, mis_size,
gb_per_s, ...) are excluded: they describe results, not identity.  Values
are normalised to µs at write time so one threshold vocabulary covers
``us_per_call`` and ``solve_ms`` rows alike.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

# default history root, relative to the CWD the bench runs from (the repo
# root, for `python -m benchmarks.run`); override with BENCH_HISTORY_DIR,
# empty string disables the history append (snapshot still written)
HISTORY_DIR_ENV = "BENCH_HISTORY_DIR"
DEFAULT_HISTORY_DIR = "BENCH_history"

# metric fields a bench row may carry, with the factor that converts each
# to µs.  One record is appended per metric present in a row.
METRIC_FIELDS: Tuple[Tuple[str, float], ...] = (
    ("us_per_call", 1.0),
    ("us_per_round", 1.0),
    ("solve_ms", 1e3),
    ("repair_ms", 1e3),
    ("cold_ms", 1e3),
    ("warm_s", 1e6),
    ("cold_s", 1e6),
)
_METRIC_NAMES = frozenset(m for m, _ in METRIC_FIELDS)

# row fields that are *outcomes* of a run, not configuration — excluded
# from the identity key (two runs of the same config legitimately differ
# on these, and keying on them would make every run its own key)
OUTCOME_FIELDS = frozenset({
    "rounds", "mis_size", "gb_per_s", "tile_payload_bytes", "touched",
    "n_add", "n_remove", "repair_rounds", "cold_rounds", "repair_mis",
    "cold_mis", "repair_valid", "rounds_summary", "speedup", "compiles",
    "plan_cache", "cold_graphs_per_s", "warm_graphs_per_s",
    "tiles_dense", "tiles_sparse", "ok",
})

# default thresholds: a key regresses when head-median exceeds
# base-median by BOTH the relative bar and the absolute floor.  0.6
# relative sits between CPU-timer noise (~1.3x observed across identical
# quick runs) and the 2x injected-slowdown the CI self-test must catch;
# the 200 µs floor keeps sub-100 µs micro-kernels from gating on jitter.
DEFAULT_REL_BAR = 0.6
DEFAULT_ABS_FLOOR_US = 200.0

_ENV_CACHE: Optional[Dict[str, object]] = None


def _git_sha() -> str:
    sha = os.environ.get("GIT_SHA", "")
    if sha:
        return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=5,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:  # noqa: BLE001 - no git / not a repo: stamp unknown
        pass
    return "unknown"


def bench_env() -> Dict[str, object]:
    """The attribution header every snapshot and history record carries.

    Cached per process: one git subprocess, one jax import — and all rows
    of one run share one timestamp, so a run is a point, not a smear.
    """
    global _ENV_CACHE
    if _ENV_CACHE is None:
        try:
            import jax

            backend = jax.default_backend()
            jax_version = jax.__version__
        except Exception:  # noqa: BLE001 - benches can run jax-free paths
            backend, jax_version = "none", "none"
        _ENV_CACHE = dict(
            git_sha=_git_sha(),
            timestamp=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            backend=backend,
            jax_version=jax_version,
        )
    return dict(_ENV_CACHE)


def stamp(doc: Dict[str, object]) -> Dict[str, object]:
    """Return a copy of `doc` with schema + env header fields attached.

    Existing keys win: a bench that already sets ``backend`` (core_bench
    does) keeps its own value — the stamp fills, never overwrites.
    """
    out = dict(schema_version=SCHEMA_VERSION, **bench_env())
    out.update(doc)
    return out


def _identity_key(bench: str, row: Dict[str, object],
                  header: Dict[str, object]) -> str:
    parts = {
        "bench": bench,
        "backend": header.get("backend", "none"),
        "quick": header.get("quick", ""),
    }
    for k, v in row.items():
        if k in _METRIC_NAMES or k in OUTCOME_FIELDS:
            continue
        if isinstance(v, (dict, list, tuple)):
            continue
        parts[k] = v
    return " ".join(f"{k}={parts[k]}" for k in sorted(parts))


def history_records(doc: Dict[str, object]) -> List[Dict[str, object]]:
    """Explode a stamped bench doc into per-(row, metric) history records."""
    bench = str(doc.get("bench", "unknown"))
    rows = doc.get("results", [])
    if not isinstance(rows, list):
        return []
    head = {k: doc.get(k) for k in
            ("git_sha", "timestamp", "backend", "jax_version", "quick")}
    records = []
    for row in rows:
        if not isinstance(row, dict):
            continue
        key = _identity_key(bench, row, head)
        for metric, to_us in METRIC_FIELDS:
            v = row.get(metric)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                records.append(dict(
                    schema=SCHEMA_VERSION, bench=bench, key=key,
                    metric=metric, value_us=round(float(v) * to_us, 3),
                    **head,
                ))
    return records


def history_path(bench: str, history_dir: str) -> str:
    return os.path.join(history_dir, f"{bench}.jsonl")


def append_history(doc: Dict[str, object],
                   history_dir: Optional[str] = None) -> int:
    """Append the doc's records to ``<history_dir>/<bench>.jsonl``.

    Returns the number of records appended; 0 when history is disabled
    (``BENCH_HISTORY_DIR=""``) or the doc has no metric rows.
    """
    if history_dir is None:
        history_dir = os.environ.get(HISTORY_DIR_ENV, DEFAULT_HISTORY_DIR)
    if not history_dir:
        return 0
    records = history_records(doc)
    if not records:
        return 0
    os.makedirs(history_dir, exist_ok=True)
    path = history_path(str(doc.get("bench", "unknown")), history_dir)
    with open(path, "a") as f:
        for r in records:
            f.write(json.dumps(r, sort_keys=True) + "\n")
    return len(records)


def write_bench(doc: Dict[str, object], out_path: str,
                history_dir: Optional[str] = None) -> Dict[str, object]:
    """The one bench emission seam: stamp, snapshot, history-append.

    Returns the stamped doc (callers that post-process — core_bench's
    overhead guard — read fields off it).
    """
    stamped = stamp(doc)
    with open(out_path, "w") as f:
        json.dump(stamped, f, indent=2)
    print(f"# wrote {out_path}")
    n = append_history(stamped, history_dir)
    if n:
        hd = history_dir or os.environ.get(HISTORY_DIR_ENV,
                                           DEFAULT_HISTORY_DIR)
        print(f"# appended {n} records to "
              f"{history_path(str(stamped.get('bench', 'unknown')), hd)}")
    return stamped


# ---------------------------------------------------------------------------
# bench-diff
# ---------------------------------------------------------------------------


def load_records(path: str) -> List[Dict[str, object]]:
    """Load history records from a ``.jsonl`` file or a directory of them.

    Unknown schema versions and malformed lines are skipped (a newer
    writer must not brick an older differ); missing paths raise.
    """
    paths: List[str] = []
    if os.path.isdir(path):
        paths = sorted(
            os.path.join(path, f) for f in os.listdir(path)
            if f.endswith(".jsonl")
        )
    else:
        paths = [path]
    records = []
    for p in paths:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    r = json.loads(line)
                except ValueError:
                    continue
                if not isinstance(r, dict):
                    continue
                if r.get("schema") != SCHEMA_VERSION:
                    continue
                if "key" in r and "metric" in r and "value_us" in r:
                    records.append(r)
    return records


def _median(vals: Sequence[float]) -> float:
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def _group(records: Sequence[Dict[str, object]]) -> Dict[Tuple[str, str],
                                                         List[float]]:
    out: Dict[Tuple[str, str], List[float]] = {}
    for r in records:
        out.setdefault((str(r["key"]), str(r["metric"])), []).append(
            float(r["value_us"]))
    return out


def diff(base: Sequence[Dict[str, object]],
         head: Sequence[Dict[str, object]],
         rel_bar: float = DEFAULT_REL_BAR,
         abs_floor_us: float = DEFAULT_ABS_FLOOR_US) -> Dict[str, object]:
    """Compare two record sets key-by-key, median-of-k per side.

    A key REGRESSES when head-median exceeds base-median by more than
    ``rel_bar`` relatively AND ``abs_floor_us`` absolutely (both bars must
    trip — relative-only flags micro-kernel jitter, absolute-only misses
    slow large ops drifting a few percent).  Improvements use the same
    bars mirrored, reported but never failing.  ``status`` is one of
    ``"ok" | "regression" | "no-overlap"``.
    """
    gb, gh = _group(base), _group(head)
    common = sorted(set(gb) & set(gh))
    rows = []
    regressions, improvements = [], []
    for key, metric in common:
        b, h = _median(gb[(key, metric)]), _median(gh[(key, metric)])
        delta = h - b
        ratio = h / b if b > 0 else float("inf")
        verdict = "same"
        if delta > abs_floor_us and h > b * (1.0 + rel_bar):
            verdict = "regression"
        elif -delta > abs_floor_us and b > h * (1.0 + rel_bar):
            verdict = "improvement"
        row = dict(key=key, metric=metric,
                   base_us=round(b, 3), head_us=round(h, 3),
                   ratio=round(ratio, 3),
                   base_k=len(gb[(key, metric)]),
                   head_k=len(gh[(key, metric)]),
                   verdict=verdict)
        rows.append(row)
        if verdict == "regression":
            regressions.append(row)
        elif verdict == "improvement":
            improvements.append(row)
    status = ("no-overlap" if not common
              else "regression" if regressions else "ok")
    return dict(
        status=status,
        n_common=len(common),
        n_base_only=len(set(gb) - set(gh)),
        n_head_only=len(set(gh) - set(gb)),
        rel_bar=rel_bar,
        abs_floor_us=abs_floor_us,
        regressions=regressions,
        improvements=improvements,
        rows=rows,
    )


def render_diff(report: Dict[str, object]) -> str:
    """Human-readable bench-diff report (the non-``--json`` output)."""
    lines = [
        f"bench-diff: {report['n_common']} comparable keys "
        f"(+{report['n_head_only']} head-only, "
        f"-{report['n_base_only']} base-only), "
        f"bars: x{1.0 + float(report['rel_bar']):.2f} rel "
        f"and {float(report['abs_floor_us']):.0f}us abs",
    ]
    for kind, rows in (("REGRESSION", report["regressions"]),
                       ("improvement", report["improvements"])):
        for r in rows:
            lines.append(
                f"  {kind}: {r['key']} [{r['metric']}] "
                f"{r['base_us']:.1f}us -> {r['head_us']:.1f}us "
                f"(x{r['ratio']:.2f}, k={r['base_k']}/{r['head_k']})"
            )
    lines.append(f"verdict: {report['status']}")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    """``python -m repro.obs bench-diff <base> <head>`` entry point.

    Exit 0 = ok (improvements included), 1 = regression, 2 = no
    overlapping keys (a mis-pointed baseline must fail LOUDLY in CI, not
    pass vacuously).
    """
    p = argparse.ArgumentParser(
        prog="repro.obs bench-diff",
        description="Compare two bench-history JSONL files/dirs; "
                    "exit 1 on regression.",
    )
    p.add_argument("base", help="baseline history .jsonl file or directory")
    p.add_argument("head", help="candidate history .jsonl file or directory")
    p.add_argument("--rel-bar", type=float, default=DEFAULT_REL_BAR,
                   help="relative slowdown bar (0.6 = fail past 1.6x)")
    p.add_argument("--abs-floor-us", type=float,
                   default=DEFAULT_ABS_FLOOR_US,
                   help="absolute slowdown floor in microseconds")
    p.add_argument("--json", action="store_true",
                   help="emit the full report as JSON instead of text")
    args = p.parse_args(argv)

    try:
        base = load_records(args.base)
        head = load_records(args.head)
    except OSError as e:
        print(f"bench-diff: cannot read history: {e}", file=sys.stderr)
        return 2

    report = diff(base, head, rel_bar=args.rel_bar,
                  abs_floor_us=args.abs_floor_us)
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(render_diff(report))
    if report["status"] == "no-overlap":
        return 2
    return 1 if report["status"] == "regression" else 0


if __name__ == "__main__":
    sys.exit(main())

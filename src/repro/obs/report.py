"""`python -m repro.obs report trace.jsonl` — render traces for humans.

Three record kinds land in one JSONL stream (`JsonlWriter`):

    {"kind": "trace",   "request_id": ..., "spans": [...]}
    {"kind": "rounds",  "rounds": R, "alive": [...], ...}
    {"kind": "metrics", "metrics": {...}}

The report renders each in order: trace records as an indented span tree
with durations, rounds records as a per-round table plus a sparkline of
the alive series, metrics records as a name → value table.  Exit code 2
when the file holds no renderable records — the CI smoke step relies on
that to catch an empty pipe.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from .rounds import RoundTrace

_SPARK = "▁▂▃▄▅▆▇█"


def _sparkline(values: List[int]) -> str:
    if not values:
        return ""
    hi = max(values)
    if hi <= 0:
        return _SPARK[0] * len(values)
    return "".join(_SPARK[min(int(v * 8 / hi), 7)] for v in values)


def render_trace(d: Dict, out) -> None:
    rid = d.get("request_id") or "-"
    spans = d.get("spans", [])
    total = max((s["start_ms"] + s["dur_ms"] for s in spans), default=0.0)
    out.write(f"trace {rid}  ({total:.2f} ms, {len(spans)} spans)\n")
    for s in spans:
        indent = "  " * (int(s.get("depth", 0)) + 1)
        meta = s.get("meta") or {}
        tail = ("  " + " ".join(f"{k}={v}" for k, v in sorted(meta.items()))) if meta else ""
        out.write(f"{indent}{s['name']:<20} {s['dur_ms']:>9.3f} ms{tail}\n")


def render_rounds(d: Dict, out) -> None:
    rt = RoundTrace.from_dict(d)
    s = rt.summary()
    out.write(
        f"rounds {rt.rounds}"
        f"  alive {s.get('alive0', 0)}→{s.get('alive_final', 0)}"
        f"  selected {s.get('selected_total', 0)}"
    )
    if rt.tiles_total:
        out.write(f"  tiles_skipped {s['tiles_skipped_mean']}/{rt.tiles_total}")
    out.write("\n")
    out.write(f"  alive    {_sparkline(rt.alive)}\n")
    out.write(f"  frontier {_sparkline(rt.frontier)}\n")
    out.write(f"  {'r':>4} {'alive':>8} {'frontier':>8} {'selected':>8} {'skipped':>8}\n")
    for r in range(rt.rounds):
        out.write(
            f"  {r:>4} {rt.alive[r]:>8} {rt.frontier[r]:>8}"
            f" {rt.selected[r]:>8} {rt.tiles_skipped[r]:>8}\n"
        )


def render_metrics(d: Dict, out) -> None:
    metrics = d.get("metrics", {})
    out.write(f"metrics ({len(metrics)} instruments)\n")
    for name, val in sorted(metrics.items()):
        if isinstance(val, dict):
            val = " ".join(f"{k}={v}" for k, v in val.items() if v is not None)
        out.write(f"  {name:<44} {val}\n")


def report(path: str, out=None) -> int:
    """Render every record in `path`; return the count rendered."""
    out = out or sys.stdout
    rendered = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                out.write(f"! line {lineno}: bad JSON ({e})\n")
                continue
            kind = d.get("kind")
            if kind == "trace":
                render_trace(d, out)
            elif kind == "rounds":
                render_rounds(d, out)
            elif kind == "metrics":
                render_metrics(d, out)
            else:
                out.write(f"! line {lineno}: unknown kind {kind!r}\n")
                continue
            rendered += 1
    return rendered


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="render repro.obs JSONL telemetry (trace tree, "
                    "per-round series, metrics tables)",
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="render a JSONL telemetry file")
    rp.add_argument("path", help="JSONL file written by the service / solver")
    args = p.parse_args(argv)

    if args.cmd == "report":
        n = report(args.path)
        if n == 0:
            print(f"# no renderable records in {args.path}", file=sys.stderr)
            return 2
        print(f"# rendered {n} records")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())

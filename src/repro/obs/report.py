"""`python -m repro.obs report trace.jsonl` — render telemetry for humans.

Three record kinds land in one JSONL stream (`JsonlWriter`):

    {"kind": "trace",   "request_id": ..., "spans": [...]}
    {"kind": "rounds",  "rounds": R, "alive": [...], ...}
    {"kind": "metrics", "metrics": {...}}

plus bench-history records (no ``kind`` — `repro.obs.bench` schema with
``key``/``metric``/``value_us``), so ``report`` pointed at a
``BENCH_history/*.jsonl`` file renders a bench section too.

The report renders each in order: trace records as an indented span tree
with durations, rounds records as a per-round table plus a sparkline of
the alive series, metrics records as a name → value table whose histogram
entries form the *health* section (count/mean/p50/p95/p99), bench records
as one timing line each.  ``--json`` swaps the human rendering for one
machine-readable document.  Exit code 2 when the file holds no renderable
records — the CI smoke step relies on that to catch an empty pipe.

``python -m repro.obs bench-diff <base> <head>`` (the CI regression gate)
dispatches to `repro.obs.bench`; see there for thresholds and exit codes.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from .rounds import RoundTrace

_SPARK = "▁▂▃▄▅▆▇█"


def _sparkline(values: List[int]) -> str:
    """Unicode mini-chart; total-safe for empty, single-point, all-zero
    and negative-valued series (negatives clamp to the bottom glyph)."""
    if not values:
        return ""
    hi = max(values)
    if hi <= 0:
        return _SPARK[0] * len(values)
    return "".join(_SPARK[min(max(int(v * 8 / hi), 0), 7)] for v in values)


def render_trace(d: Dict, out) -> None:
    rid = d.get("request_id") or "-"
    spans = d.get("spans", [])
    total = max((s["start_ms"] + s["dur_ms"] for s in spans), default=0.0)
    out.write(f"trace {rid}  ({total:.2f} ms, {len(spans)} spans)\n")
    for s in spans:
        indent = "  " * (int(s.get("depth", 0)) + 1)
        meta = s.get("meta") or {}
        tail = ("  " + " ".join(f"{k}={v}" for k, v in sorted(meta.items()))) if meta else ""
        out.write(f"{indent}{s['name']:<20} {s['dur_ms']:>9.3f} ms{tail}\n")


def render_rounds(d: Dict, out) -> None:
    rt = RoundTrace.from_dict(d)
    s = rt.summary()
    if not rt.rounds:
        # a 0-round trace is legal (empty graph / no-op update): summary()
        # carries no per-round keys, so bail before indexing any
        out.write("rounds 0  (empty trace)\n")
        return
    out.write(
        f"rounds {rt.rounds}"
        f"  alive {s.get('alive0', 0)}→{s.get('alive_final', 0)}"
        f"  selected {s.get('selected_total', 0)}"
    )
    if rt.tiles_total and s.get("tiles_skipped_mean") is not None:
        out.write(f"  tiles_skipped {s['tiles_skipped_mean']}/{rt.tiles_total}")
    out.write("\n")
    out.write(f"  alive    {_sparkline(rt.alive)}\n")
    out.write(f"  frontier {_sparkline(rt.frontier)}\n")
    out.write(f"  {'r':>4} {'alive':>8} {'frontier':>8} {'selected':>8} {'skipped':>8}\n")
    for r in range(rt.rounds):
        out.write(
            f"  {r:>4} {rt.alive[r]:>8} {rt.frontier[r]:>8}"
            f" {rt.selected[r]:>8} {rt.tiles_skipped[r]:>8}\n"
        )


def _fmt_histogram(val: Dict) -> str:
    """Health-section one-liner for a histogram snapshot: the SLO view."""
    if not val.get("count"):
        return "n=0"
    parts = [f"n={val['count']}"]
    for k in ("mean", "p50", "p95", "p99", "max"):
        if val.get(k) is not None:
            parts.append(f"{k}={val[k]}")
    return " ".join(parts)


def render_metrics(d: Dict, out) -> None:
    metrics = d.get("metrics", {})
    out.write(f"metrics ({len(metrics)} instruments)\n")
    for name, val in sorted(metrics.items()):
        if isinstance(val, dict):
            # histogram snapshot → the health line (quantiles, not the
            # raw bucket vector — promtext carries that)
            val = _fmt_histogram(val)
        out.write(f"  {name:<44} {val}\n")


def render_bench(d: Dict, out) -> None:
    """One bench-history record → one timing line."""
    out.write(
        f"bench {d.get('key', '?')} [{d.get('metric', '?')}]"
        f" {d.get('value_us', 0.0)}us"
        f"  @{d.get('git_sha', '?')} {d.get('timestamp', '?')}\n"
    )


def _classify(d: Dict) -> str:
    kind = d.get("kind")
    if kind in ("trace", "rounds", "metrics"):
        return kind
    if kind is None and "metric" in d and "value_us" in d:
        return "bench"
    return "unknown"


_RENDERERS = {
    "trace": render_trace,
    "rounds": render_rounds,
    "metrics": render_metrics,
    "bench": render_bench,
}


def _load(path: str, out) -> List[Dict]:
    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                out.write(f"! line {lineno}: bad JSON ({e})\n")
                continue
            if not isinstance(d, dict):
                out.write(f"! line {lineno}: not an object\n")
                continue
            records.append(d)
    return records


def report(path: str, out=None) -> int:
    """Render every record in `path`; return the count rendered."""
    out = out or sys.stdout
    rendered = 0
    for d in _load(path, out):
        kind = _classify(d)
        fn = _RENDERERS.get(kind)
        if fn is None:
            out.write(f"! unknown kind {d.get('kind')!r}\n")
            continue
        fn(d, out)
        rendered += 1
    return rendered


def report_json(path: str, out=None) -> Dict:
    """Machine-readable digest: per-kind counts + the parsed records, with
    rounds records augmented by their `RoundTrace.summary()` scalars."""
    out = out or sys.stdout
    counts: Dict[str, int] = {}
    records = []
    for d in _load(path, out=_NullOut()):
        kind = _classify(d)
        if kind == "unknown":
            continue
        counts[kind] = counts.get(kind, 0) + 1
        if kind == "rounds":
            try:
                d = dict(d, summary=RoundTrace.from_dict(d).summary())
            except (KeyError, ValueError, TypeError):
                pass
        records.append(d)
    return dict(path=path, n_records=len(records), counts=counts,
                records=records)


class _NullOut:
    def write(self, _s: str) -> None:
        pass


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "bench-diff":
        # the regression gate has its own argparse (thresholds, --json):
        # hand the remaining argv straight over so its --help stays whole
        from . import bench

        return bench.main(argv[1:])

    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="render repro.obs JSONL telemetry (trace tree, "
                    "per-round series, metrics/health tables, bench "
                    "history); `bench-diff` compares two history files",
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="render a JSONL telemetry file")
    rp.add_argument("path", help="JSONL file written by the service / solver")
    rp.add_argument("--json", action="store_true",
                    help="emit a machine-readable JSON digest instead")
    sub.add_parser("bench-diff",
                   help="compare two bench-history files (see bench-diff "
                        "--help); exit 1 on regression")
    args = p.parse_args(argv)

    if args.cmd == "report":
        if args.json:
            doc = report_json(args.path)
            print(json.dumps(doc, indent=2))
            return 0 if doc["n_records"] else 2
        n = report(args.path)
        if n == 0:
            print(f"# no renderable records in {args.path}", file=sys.stderr)
            return 2
        print(f"# rendered {n} records")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())

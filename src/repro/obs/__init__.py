"""repro.obs — observability for the TC-MIS stack (DESIGN.md §14).

Three legs, importable independently:

* `rounds`  — on-device round-telemetry buffer layout + host `RoundTrace`
              (numpy-only; `core.engine` imports its column constants)
* `trace`   — `Trace` / `trace_span` span tracing + JSONL export
* `metrics` — `MetricsRegistry` counters/gauges/histograms + the
              process-wide `REGISTRY`

`python -m repro.obs report trace.jsonl` renders the JSONL stream.
"""
from .metrics import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry
from .rounds import (
    COL_ALIVE,
    COL_FRONTIER,
    COL_SELECTED,
    COL_TILES_SKIPPED,
    COLUMN_NAMES,
    TELEMETRY_COLS,
    TELEMETRY_FILL,
    RoundTrace,
)
from .trace import JsonlWriter, Span, Trace, trace_span

__all__ = [
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "COL_ALIVE",
    "COL_FRONTIER",
    "COL_SELECTED",
    "COL_TILES_SKIPPED",
    "COLUMN_NAMES",
    "TELEMETRY_COLS",
    "TELEMETRY_FILL",
    "RoundTrace",
    "JsonlWriter",
    "Span",
    "Trace",
    "trace_span",
]

"""repro.obs — observability for the TC-MIS stack (DESIGN.md §14, §17).

Five legs, importable independently:

* `rounds`   — on-device round-telemetry buffer layout + host `RoundTrace`
               (numpy-only; `core.engine` imports its column constants)
* `trace`    — `Trace` / `trace_span` span tracing + JSONL export
* `metrics`  — `MetricsRegistry` counters/gauges/fixed-bucket histograms
               (p50/p95/p99) + the process-wide `REGISTRY`
* `bench`    — stamped bench snapshots, the append-only `BENCH_history/`
               store, and the `bench-diff` regression gate
* `promtext` — Prometheus text exposition over a metrics snapshot

`python -m repro.obs report trace.jsonl` renders the JSONL stream;
`python -m repro.obs bench-diff <base> <head>` gates perf regressions.
"""
from .bench import append_history, bench_env, diff, load_records, stamp, write_bench
from .metrics import (
    DEFAULT_BUCKETS,
    QUANTILES,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .promtext import to_promtext, write_promtext
from .rounds import (
    COL_ALIVE,
    COL_FRONTIER,
    COL_SELECTED,
    COL_TILES_SKIPPED,
    COLUMN_NAMES,
    TELEMETRY_COLS,
    TELEMETRY_FILL,
    RoundTrace,
)
from .trace import JsonlWriter, Span, Trace, trace_span

__all__ = [
    "DEFAULT_BUCKETS",
    "QUANTILES",
    "REGISTRY",
    "append_history",
    "bench_env",
    "diff",
    "load_records",
    "stamp",
    "write_bench",
    "to_promtext",
    "write_promtext",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "COL_ALIVE",
    "COL_FRONTIER",
    "COL_SELECTED",
    "COL_TILES_SKIPPED",
    "COLUMN_NAMES",
    "TELEMETRY_COLS",
    "TELEMETRY_FILL",
    "RoundTrace",
    "JsonlWriter",
    "Span",
    "Trace",
    "trace_span",
]

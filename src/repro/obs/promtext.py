"""Prometheus text exposition over a metrics snapshot (DESIGN.md §17).

`to_promtext(snapshot)` renders the flat dict `MISService.metrics_snapshot()`
(or any `MetricsRegistry.snapshot()`) returns into the Prometheus text
format, version 0.0.4 — the format node_exporter's textfile collector and
every Prometheus-compatible scraper ingest.  `write_promtext` is the
textfile-export seam the serving CLI's ``--metrics-path`` flag drives:
atomically replace one ``.prom`` file per process, point the collector's
glob at it, done — no HTTP listener inside the solver process.

Naming rules (stable — dashboards key on these):

* every metric is prefixed ``repro_``; registry dots become underscores
  (``service.queue_ms`` → ``repro_service_queue_ms``), any other
  non-``[a-zA-Z0-9_]`` character becomes ``_`` too;
* counters (int snapshots) get the conventional ``_total`` suffix;
* gauges (float snapshots) export verbatim;
* histograms (dict snapshots with ``buckets``) export the classic triplet —
  cumulative ``_bucket{le="..."}`` series ending at ``le="+Inf"``, ``_sum``
  and ``_count`` — PLUS ``{quantile="0.5|0.95|0.99"}`` gauge-style lines
  from the snapshot's p50/p95/p99 upper-bound estimates, so SLO panels can
  plot quantiles without a PromQL `histogram_quantile` round-trip.

The kind is recovered from the snapshot VALUE SHAPE (int / float / dict):
snapshots deliberately carry no side-channel type table, and the shape
mapping is exact for the three instrument kinds `repro.obs.metrics` emits.
"""
from __future__ import annotations

import os
import re
from typing import Dict

PREFIX = "repro_"

_INVALID = re.compile(r"[^a-zA-Z0-9_]")


def metric_name(name: str, prefix: str = PREFIX) -> str:
    """Sanitised exposition name: prefix + dots/invalid chars → ``_``."""
    out = prefix + _INVALID.sub("_", name)
    if out[0].isdigit():
        out = "_" + out
    return out


def _fmt(v) -> str:
    """Prometheus number formatting (ints bare, floats via repr)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def _histogram_lines(name: str, snap: Dict) -> list:
    lines = [f"# TYPE {name} histogram"]
    for le, cum in snap.get("buckets", []):
        le_s = le if isinstance(le, str) else _fmt(float(le))
        lines.append(f'{name}_bucket{{le="{le_s}"}} {cum}')
    if not snap.get("buckets"):
        # empty histogram: still expose the +Inf bucket so the series exists
        lines.append(f'{name}_bucket{{le="+Inf"}} 0')
    lines.append(f"{name}_sum {_fmt(snap.get('total', 0.0))}")
    lines.append(f"{name}_count {snap.get('count', 0)}")
    for q, key in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
        if snap.get(key) is not None:
            lines.append(f'{name}{{quantile="{q}"}} {_fmt(snap[key])}')
    return lines


def to_promtext(snapshot: Dict[str, object], prefix: str = PREFIX) -> str:
    """Render a metrics snapshot as Prometheus exposition text.

    Deterministic output (names sorted) so repeated exports of the same
    state are byte-identical — textfile collectors diff on mtime+content.
    """
    lines = []
    for raw, val in sorted(snapshot.items()):
        name = metric_name(raw, prefix)
        if isinstance(val, dict):
            lines += _histogram_lines(name, val)
        elif isinstance(val, bool) or isinstance(val, int):
            lines.append(f"# TYPE {name}_total counter")
            lines.append(f"{name}_total {_fmt(val)}")
        elif isinstance(val, float):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {_fmt(val)}")
        # non-numeric, non-dict values (shouldn't occur) are skipped: the
        # exposition format has no string samples
    return "\n".join(lines) + "\n" if lines else ""


def write_promtext(
    snapshot: Dict[str, object], path: str, prefix: str = PREFIX
) -> None:
    """Atomic textfile export: write to a temp sibling, `os.replace` into
    place — a scraper never reads a half-written file."""
    text = to_promtext(snapshot, prefix)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)

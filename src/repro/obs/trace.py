"""Span tracing: nested wall-clock phases for one request, JSONL export.

A `Trace` is a per-request recorder; `trace_span(trace, "plan")` is the one
instrumentation primitive, a context manager that times its body and
appends a `Span` with the current nesting depth.  Passing ``trace=None``
(the default everywhere) makes it a no-op with no timer reads, so the
untraced hot path pays one `is None` check per seam.

Span taxonomy (DESIGN.md §14) — names are dotted, layer-first:

    service.step            one queue drain
      service.batch         one packed bucket (meta: bucket, batch_size)
    solver.solve            one front-door call
      solver.plan           plan-cache lookup / tiling build
      solver.pack           block-diagonal batch packing
      solver.compile        cold-path lower().compile() (AOT; cache misses only)
      solver.execute        compiled-program dispatch + block_until_ready
      solver.validate       response validity check
    solver.update           dyngraph repair route (meta: mode)

The conflated pre-PR `solve_ms` split: on a compile-stat miss ("compiled",
the existing `_note_signature` signal) the solver lowers and compiles
ahead-of-time under `solver.compile`, then executes the compiled program
under `solver.execute`; on a hit, only `solver.execute` appears.

Optional `jax.profiler` bridge: `Trace(profiler=True)` wraps each span in
`jax.profiler.TraceAnnotation` so spans land in any surrounding profiler
capture.  Import is lazy and failure-tolerant — tracing never takes the
solver down.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Span:
    name: str
    start_ms: float          # offset from trace start
    dur_ms: float
    depth: int
    meta: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        d = dict(
            name=self.name,
            start_ms=round(self.start_ms, 3),
            dur_ms=round(self.dur_ms, 3),
            depth=self.depth,
        )
        if self.meta:
            d["meta"] = self.meta
        return d


class Trace:
    """Per-request span recorder.  Not thread-safe by design — one Trace
    belongs to one request flowing through one service step."""

    def __init__(self, request_id: str = "", *, profiler: bool = False):
        self.request_id = request_id
        self.spans: List[Span] = []
        self._t0 = time.perf_counter()
        self._depth = 0
        self._annot = None
        if profiler:
            try:
                from jax.profiler import TraceAnnotation
                self._annot = TraceAnnotation
            except Exception:
                self._annot = None

    # -- recording --------------------------------------------------------

    @contextmanager
    def span(self, name: str, **meta):
        start = time.perf_counter()
        self._depth += 1
        annot = self._annot(name) if self._annot is not None else None
        if annot is not None:
            annot.__enter__()
        try:
            yield self
        finally:
            if annot is not None:
                annot.__exit__(None, None, None)
            self._depth -= 1
            end = time.perf_counter()
            self.spans.append(Span(
                name=name,
                start_ms=(start - self._t0) * 1e3,
                dur_ms=(end - start) * 1e3,
                depth=self._depth,
                meta={k: v for k, v in meta.items() if v is not None},
            ))

    def note(self, name: str, dur_ms: float, **meta) -> None:
        """Record an already-measured duration as a span (for timings that
        come from outside the context manager, e.g. a queue wait)."""
        self.spans.append(Span(
            name=name,
            start_ms=(time.perf_counter() - self._t0) * 1e3 - dur_ms,
            dur_ms=float(dur_ms),
            depth=self._depth,
            meta={k: v for k, v in meta.items() if v is not None},
        ))

    # -- query ------------------------------------------------------------

    def total_ms(self, name: str) -> float:
        return sum(s.dur_ms for s in self.spans if s.name == name)

    # -- export -----------------------------------------------------------

    def to_dict(self) -> Dict[str, object]:
        # spans are appended at exit, i.e. children before parents; emit in
        # start order so the report tree reads top-down
        ordered = sorted(self.spans, key=lambda s: s.start_ms)
        return dict(
            request_id=self.request_id,
            spans=[s.to_dict() for s in ordered],
        )

    def to_jsonl_line(self) -> str:
        return json.dumps({"kind": "trace", **self.to_dict()}, sort_keys=True)


@contextmanager
def trace_span(trace: Optional[Trace], name: str, **meta):
    """`with trace_span(trace, "solver.plan"): ...` — no-op when trace is
    None.  The single seam primitive every layer uses."""
    if trace is None:
        yield None
        return
    with trace.span(name, **meta):
        yield trace


class JsonlWriter:
    """Append-only JSONL sink for trace / rounds / metrics records.

    Opens lazily on first write so constructing a service with a trace path
    configured but never exercised leaves no empty file behind."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def write_line(self, line: str) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write(line + "\n")
        self._fh.flush()

    def write_trace(self, trace: Trace) -> None:
        self.write_line(trace.to_jsonl_line())

    def write_rounds(self, rt) -> None:
        self.write_line(rt.to_jsonl_line())

    def write_metrics(self, snapshot: Dict[str, object]) -> None:
        self.write_line(json.dumps(
            {"kind": "metrics", "metrics": snapshot}, sort_keys=True))

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

"""Round-telemetry buffer layout + the host-side `RoundTrace` view.

The device side (DESIGN.md §14): when `SolveOptions.telemetry` is on,
`_tc_mis_impl` threads a fixed-shape ``(max_rounds, TELEMETRY_COLS)`` int32
buffer through the round `while_loop`.  Each executed round r writes row r
with six cheap reductions over state the round body already holds —
no extra SpMVs, no host callbacks, ONE device→host transfer at the
epilogue:

    col 0  COL_ALIVE          popcount(alive) at round entry
    col 1  COL_FRONTIER       popcount(candidates C) — the phase-① frontier
    col 2  COL_SELECTED       popcount(in_mis_new) − popcount(in_mis_old)
    col 3  COL_TILES_SKIPPED  n_tiles − Σ col_flags[tile_cols]  (0 when the
                              engine computes no flags, e.g. segment)
    col 4  COL_TILES_DENSE    tiles dispatched on the dense path this round
                              (hybrid: the compacted dense partition minus
                              its skipped tiles; non-hybrid: n_tiles −
                              skipped)
    col 5  COL_TILES_SPARSE   tiles routed through the COO/segment tail
                              (0 outside hybrid)

Rows past the executed round count stay at the fill value −1, which is how
`RoundTrace.from_buffer` distinguishes "round never ran" from a legitimate
all-zero round without needing the loop counter on-device.

This module is deliberately import-light (numpy only): `core.engine` pulls
the column constants from here, so any jax / repro.core import would be a
layering cycle.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

TELEMETRY_COLS = 6
COL_ALIVE = 0
COL_FRONTIER = 1
COL_SELECTED = 2
COL_TILES_SKIPPED = 3
COL_TILES_DENSE = 4
COL_TILES_SPARSE = 5

# rows beyond the executed rounds keep this fill; col 0 (alive) is never
# negative for an executed round, so it doubles as the row-validity mark
TELEMETRY_FILL = -1

COLUMN_NAMES = (
    "alive", "frontier", "selected", "tiles_skipped",
    "tiles_dense", "tiles_sparse",
)


@dataclass(frozen=True)
class RoundTrace:
    """Host-side per-round series for one solve.

    ``alive[r]`` etc. are python lists of ints, length == ``rounds`` — the
    executed prefix of the device buffer, already validated and trimmed.
    """

    rounds: int
    alive: List[int]
    frontier: List[int]
    selected: List[int]
    tiles_skipped: List[int]
    tiles_dense: List[int] = field(default_factory=list)
    tiles_sparse: List[int] = field(default_factory=list)
    tiles_total: int = 0
    meta: Dict[str, object] = field(default_factory=dict)

    @classmethod
    def from_buffer(
        cls,
        buf,
        rounds: int,
        *,
        tiles_total: int = 0,
        meta: Optional[Dict[str, object]] = None,
    ) -> "RoundTrace":
        """Trim the raw ``(max_rounds, K)`` device buffer to the executed
        prefix.  ``rounds`` comes from the result epilogue; rows past it are
        required to still hold the fill value (a mismatch means the loop
        wrote outside its round index — worth failing loudly)."""
        a = np.asarray(buf, dtype=np.int64)
        if a.ndim != 2 or a.shape[1] != TELEMETRY_COLS:
            raise ValueError(f"telemetry buffer shape {a.shape}, want (R, {TELEMETRY_COLS})")
        rounds = int(rounds)
        if rounds < 0 or rounds > a.shape[0]:
            raise ValueError(f"rounds={rounds} outside buffer of {a.shape[0]} rows")
        used = a[:rounds]
        if used.size and (used[:, COL_ALIVE] < 0).any():
            bad = int(np.argmax(used[:, COL_ALIVE] < 0))
            raise ValueError(f"round {bad} < rounds={rounds} was never recorded")
        return cls(
            rounds=rounds,
            alive=[int(v) for v in used[:, COL_ALIVE]],
            frontier=[int(v) for v in used[:, COL_FRONTIER]],
            selected=[int(v) for v in used[:, COL_SELECTED]],
            tiles_skipped=[int(v) for v in used[:, COL_TILES_SKIPPED]],
            tiles_dense=[int(v) for v in used[:, COL_TILES_DENSE]],
            tiles_sparse=[int(v) for v in used[:, COL_TILES_SPARSE]],
            tiles_total=int(tiles_total),
            meta=dict(meta or {}),
        )

    # -- serialization ----------------------------------------------------

    def to_dict(self) -> Dict[str, object]:
        return dict(
            rounds=self.rounds,
            alive=list(self.alive),
            frontier=list(self.frontier),
            selected=list(self.selected),
            tiles_skipped=list(self.tiles_skipped),
            tiles_dense=list(self.tiles_dense),
            tiles_sparse=list(self.tiles_sparse),
            tiles_total=self.tiles_total,
            meta=dict(self.meta),
        )

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "RoundTrace":
        return cls(
            rounds=int(d["rounds"]),
            alive=[int(v) for v in d["alive"]],
            frontier=[int(v) for v in d["frontier"]],
            selected=[int(v) for v in d["selected"]],
            tiles_skipped=[int(v) for v in d["tiles_skipped"]],
            tiles_dense=[int(v) for v in d.get("tiles_dense", [])],
            tiles_sparse=[int(v) for v in d.get("tiles_sparse", [])],
            tiles_total=int(d.get("tiles_total", 0)),
            meta=dict(d.get("meta", {})),
        )

    def to_jsonl_line(self) -> str:
        return json.dumps({"kind": "rounds", **self.to_dict()}, sort_keys=True)

    @classmethod
    def from_jsonl_line(cls, line: str) -> "RoundTrace":
        d = json.loads(line)
        if d.get("kind") != "rounds":
            raise ValueError(f"not a rounds record: kind={d.get('kind')!r}")
        return cls.from_dict(d)

    # -- analysis ---------------------------------------------------------

    def summary(self) -> Dict[str, object]:
        """Compact scalars for BENCH rows / log lines: total selected, the
        frontier-shrinkage profile, and the tile-gating win."""
        if not self.rounds:
            return dict(rounds=0, selected_total=0)
        skip_frac = None
        if self.tiles_total:
            skip_frac = round(
                sum(self.tiles_skipped) / (self.tiles_total * self.rounds), 4
            )
        return dict(
            rounds=self.rounds,
            alive0=self.alive[0],
            alive_final=self.alive[-1],
            selected_total=sum(self.selected),
            frontier_peak=max(self.frontier),
            frontier_final=self.frontier[-1],
            tiles_skipped_mean=round(sum(self.tiles_skipped) / self.rounds, 1),
            tiles_skip_frac=skip_frac,
            tiles_dense_mean=(
                round(sum(self.tiles_dense) / self.rounds, 1)
                if self.tiles_dense else None
            ),
            tiles_sparse_mean=(
                round(sum(self.tiles_sparse) / self.rounds, 1)
                if self.tiles_sparse else None
            ),
        )

    def check_invariants(self) -> None:
        """The monotonicity contracts the solver guarantees (tested by
        tests/test_obs.py; also a cheap sanity hook for callers):

        * alive is non-increasing round over round;
        * every executed round selects ≥1 vertex (the global max-priority
          alive vertex always survives phase ②), so selected ≥ 1;
        * counts are bounded by alive₀.
        """
        for r in range(1, self.rounds):
            if self.alive[r] > self.alive[r - 1]:
                raise AssertionError(
                    f"alive increased at round {r}: {self.alive[r-1]} -> {self.alive[r]}"
                )
        for r in range(self.rounds):
            if self.selected[r] < 1:
                raise AssertionError(f"round {r} selected {self.selected[r]} (< 1)")
            if self.frontier[r] > self.alive[r]:
                raise AssertionError(
                    f"round {r} frontier {self.frontier[r]} > alive {self.alive[r]}"
                )

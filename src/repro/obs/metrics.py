"""The metrics registry: counters, gauges and histograms for every layer.

One `MetricsRegistry` is a flat namespace of named instruments.  The repo's
layers each own one — `Solver.metrics`, `PlanCache.metrics`,
`MISService.metrics` — so per-instance numbers never bleed between two
solvers in one process, while module-level code with no instance to hang
state on (the batcher's priority cache, the dyngraph repair-mode decision)
records into the process-wide `REGISTRY`.  `MISService.metrics_snapshot()`
merges all four views into the one operator-facing dict (DESIGN.md §14).

The legacy ad-hoc `stats` dicts (`Solver.stats`, `PlanCache.stats`,
`MISService.stats`) survive as read-only *views* over these instruments —
same keys, same ints — so nothing downstream re-learns a spelling.

Design constraints:

* **Never inside jit.**  Instruments mutate python state; a call under a
  trace would fire once per *compile*, not once per event.  Everything
  device-side goes through the round-telemetry buffer instead
  (`repro.obs.rounds`); instruments record at the eager seams only.
* Snapshots are plain JSON-able dicts: counters/gauges flatten to numbers,
  histograms to {count, total, min, max, mean} records.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-set value (queue depth, cache size, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming summary of an observed quantity (latencies, batch sizes).

    Keeps count/total/min/max — O(1) state, enough for the report CLI's
    mean/extremes rendering without a bucket scheme to mis-tune."""

    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self):
        if not self.count:
            return dict(count=0, total=0.0, min=None, max=None, mean=None)
        return dict(
            count=self.count,
            total=round(self.total, 3),
            min=round(self.min, 3),
            max=round(self.max, 3),
            mean=round(self.total / self.count, 3),
        )


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """A named, typed instrument namespace.

    `counter`/`gauge`/`histogram` are get-or-create: the first call for a
    name fixes its kind, and re-asking with a different kind is a caller
    bug, raised loudly.  Thread-safe at the registry level (instrument
    mutation itself is a GIL-atomic int/float update).
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, kind: str, name: str):
        with self._lock:
            have = self._kinds.get(name)
            if have is None:
                self._kinds[name] = kind
                self._instruments[name] = _KINDS[kind](name)
            elif have != kind:
                raise TypeError(
                    f"metric {name!r} is a {have}, requested as {kind}"
                )
            return self._instruments[name]

    def counter(self, name: str) -> Counter:
        return self._get("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._get("histogram", name)

    def snapshot(self) -> Dict[str, object]:
        """JSON-able {name: value-or-summary} of every instrument."""
        with self._lock:
            return {k: v.snapshot() for k, v in sorted(self._instruments.items())}


# The process-wide registry: the home of metrics recorded by module-level
# code (batcher priority cache, repair-mode decisions) that has no layer
# instance to own them.  Layer instances (Solver/PlanCache/MISService) own
# their OWN registries; `MISService.metrics_snapshot()` merges everything.
REGISTRY = MetricsRegistry("process")


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)

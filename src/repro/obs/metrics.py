"""The metrics registry: counters, gauges and histograms for every layer.

One `MetricsRegistry` is a flat namespace of named instruments.  The repo's
layers each own one — `Solver.metrics`, `PlanCache.metrics`,
`MISService.metrics` — so per-instance numbers never bleed between two
solvers in one process, while module-level code with no instance to hang
state on (the batcher's priority cache, the dyngraph repair-mode decision)
records into the process-wide `REGISTRY`.  `MISService.metrics_snapshot()`
merges all four views into the one operator-facing dict (DESIGN.md §14).

The legacy ad-hoc `stats` dicts (`Solver.stats`, `PlanCache.stats`,
`MISService.stats`) survive as read-only *views* over these instruments —
same keys, same ints — so nothing downstream re-learns a spelling.

Design constraints:

* **Never inside jit.**  Instruments mutate python state; a call under a
  trace would fire once per *compile*, not once per event.  Everything
  device-side goes through the round-telemetry buffer instead
  (`repro.obs.rounds`); instruments record at the eager seams only.
* Snapshots are plain JSON-able dicts: counters/gauges flatten to numbers,
  histograms to {count, total, min, max, mean, p50, p95, p99} records with
  their cumulative bucket counts (the Prometheus exposition in
  `repro.obs.promtext` renders straight from a snapshot).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Optional, Sequence, Tuple


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-set value (queue depth, cache size, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


# Fixed bucket upper edges (in the unit observed — latencies record ms).
# Log-spaced from 100 µs to 10 s plus the implicit +Inf overflow bucket:
# wide enough that one scheme serves latencies, batch sizes and fractions
# without per-instrument tuning, fine enough that p50/p95/p99 estimates land
# within one log-2.5 step of the truth (DESIGN.md §17).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)

# the quantiles every histogram snapshot carries (SLO spellings)
QUANTILES: Tuple[float, ...] = (0.5, 0.95, 0.99)


class Histogram:
    """Fixed-bucket summary of an observed quantity (latencies, batch sizes).

    Keeps count/total/min/max plus a cumulative-style fixed bucket vector
    (`bucket_counts[i]` = observations with value <= `buckets[i]`; the last
    slot is the +Inf overflow).  O(len(buckets)) state, O(log buckets) per
    observe — cheap enough for the eager seams, rich enough for p50/p95/p99
    SLO quantiles and a Prometheus histogram exposition.

    `quantile(q)` returns the UPPER EDGE of the bucket holding the q-th
    ranked observation, clamped to the observed max — an upper bound on the
    true quantile (never an under-estimate, the conservative direction for
    SLO gating) and monotone in q.  Overflow-bucket quantiles report the
    observed max (the tightest bound available)."""

    __slots__ = ("name", "buckets", "bucket_counts", "count", "total",
                 "min", "max")

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram {name!r}: buckets must be "
                             f"strictly increasing, got {buckets}")
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # last = +Inf
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        # first edge >= v, i.e. the smallest bucket with v <= le (Prometheus
        # `le` semantics); past the last edge lands in the overflow slot
        self.bucket_counts[bisect.bisect_left(self.buckets, v)] += 1

    def quantile(self, q: float) -> Optional[float]:
        """Upper-bound estimate of the q-quantile (None when empty)."""
        if not self.count:
            return None
        q = min(max(float(q), 0.0), 1.0)
        # rank of the target observation, 1-based: ceil(q * count), >= 1
        target = max(int(-(-q * self.count // 1)), 1)
        cum = 0
        for i, c in enumerate(self.bucket_counts):
            cum += c
            if cum >= target:
                if i < len(self.buckets):
                    return min(self.buckets[i], self.max)
                return self.max            # overflow: observed max is the bound
        return self.max

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram (e.g. the same instrument from a replica's
        registry) into this one.  Bucket schemes must match — merging
        differently-bucketed histograms would silently mis-bin."""
        if other.buckets != self.buckets:
            raise ValueError(
                f"histogram {self.name!r}: cannot merge bucket scheme "
                f"{other.buckets} into {self.buckets}"
            )
        for i, c in enumerate(other.bucket_counts):
            self.bucket_counts[i] += c
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def snapshot(self):
        if not self.count:
            return dict(count=0, total=0.0, min=None, max=None, mean=None,
                        p50=None, p95=None, p99=None)
        qs = {f"p{int(q * 100)}": round(self.quantile(q), 3)
              for q in QUANTILES}
        cum, cum_counts = 0, []
        for c in self.bucket_counts:
            cum += c
            cum_counts.append(cum)
        return dict(
            count=self.count,
            total=round(self.total, 3),
            min=round(self.min, 3),
            max=round(self.max, 3),
            mean=round(self.total / self.count, 3),
            **qs,
            # cumulative per-le counts, +Inf last — what promtext renders
            buckets=[
                [le, n] for le, n in
                zip(list(self.buckets) + ["+Inf"], cum_counts)
            ],
        )


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """A named, typed instrument namespace.

    `counter`/`gauge`/`histogram` are get-or-create: the first call for a
    name fixes its kind, and re-asking with a different kind is a caller
    bug, raised loudly.  Thread-safe at the registry level (instrument
    mutation itself is a GIL-atomic int/float update).
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, kind: str, name: str):
        with self._lock:
            have = self._kinds.get(name)
            if have is None:
                self._kinds[name] = kind
                self._instruments[name] = _KINDS[kind](name)
            elif have != kind:
                raise TypeError(
                    f"metric {name!r} is a {have}, requested as {kind}"
                )
            return self._instruments[name]

    def counter(self, name: str) -> Counter:
        return self._get("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._get("histogram", name)

    def snapshot(self) -> Dict[str, object]:
        """JSON-able {name: value-or-summary} of every instrument."""
        with self._lock:
            return {k: v.snapshot() for k, v in sorted(self._instruments.items())}

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry's instruments into this one, by name:
        counters add, gauges take the other's last value, histograms merge
        bucket-wise.  The cross-replica aggregation seam — a fleet
        coordinator merges per-replica registries into one before
        snapshotting/exposing.  Same-name instruments must agree on kind
        (the usual get-or-create TypeError otherwise)."""
        with other._lock:
            pairs = [(k, other._kinds[k], v)
                     for k, v in other._instruments.items()]
        for name, kind, inst in pairs:
            mine = self._get(kind, name)
            if kind == "counter":
                mine.inc(inst.value)
            elif kind == "gauge":
                mine.set(inst.value)
            else:
                mine.merge(inst)


# The process-wide registry: the home of metrics recorded by module-level
# code (batcher priority cache, repair-mode decisions) that has no layer
# instance to own them.  Layer instances (Solver/PlanCache/MISService) own
# their OWN registries; `MISService.metrics_snapshot()` merges everything.
REGISTRY = MetricsRegistry("process")


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)

"""Incremental MIS repair: re-enter the round engine from a warm state.

The frontier-driven TC line (BLEST, Graph Traversal on Tensor Cores) rests
on one observation: delta-shaped work is still SpMV-shaped.  The same holds
for MIS repair.  After an `EdgeDelta`, the prior solution is *almost* right
— only the delta endpoints and their neighbourhoods can be wrong — so
instead of a cold re-solve we seed `MISRoundState` with the prior solution
and hand the round engine a candidate set that is just the dirty frontier
(DESIGN.md §12):

  in_mis₀ = prior \\ dirty       dirty = delta endpoints.  Every NEW edge
                                 runs between dirty vertices, so the seed
                                 set is independent in the mutated graph
                                 by construction — eviction needs no
                                 conflict search.
  alive₀  = ~in_mis₀ & ~(A·in_mis₀ > 0)
                                 one SpMV pass over the PATCHED
                                 representation, on the configured
                                 engine's OWN phase-② substrate
                                 (`_covered`: Pallas kernel / segment ops /
                                 jnp oracle) — recovers exactly the
                                 vertices the seed set no longer
                                 dominates: evicted dirty vertices, their
                                 orphaned neighbours, and anything
                                 uncovered by a removed edge.

From there the unmodified engine round body (`engine.step` — any
registered engine) runs to convergence: candidates spread only through the
alive set, so a small delta converges in a handful of rounds while the
untouched bulk of the graph never re-enters phase ①.  Convergence yields a
full valid MIS of the mutated graph — maximality is global because alive₀
is computed globally, not guessed from a k-hop ball.

An EMPTY warm frontier runs zero rounds (`lax.while_loop` fails on entry),
which is what makes `repair="incremental"` on an empty delta bit-identical
to the prior (= cold) solution, per the Solver's repair contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    SegmentEngine,
    TiledPallasEngine,
    get_engine,
    resolve_frontier,
    tile_spmv,
    tile_spmv_bits,
)
from repro.core.heuristics import Priorities
from repro.core.luby import MISResult
from repro.core.tc_mis import _tc_mis_impl
from repro.core.tiling import (
    BlockTiledGraph,
    pack_frontier_words,
    pack_vertex_vector,
    tiles_as_words,
)
from repro.graphs.graph import Graph
from repro.obs import metrics as obs_metrics


def note_repair(mode: str, *, dirty_frac: float = 0.0) -> None:
    """Record one repair-mode decision in the process metrics registry
    (repro.obs).  EAGER-ONLY by contract: the Solver calls this where the
    mode is decided (before jit dispatch) — never from inside `repair_mis`
    or `warm_state`, which run under a trace and would count compiles, not
    repairs."""
    obs_metrics.counter(f"repair.{mode}").inc()
    obs_metrics.histogram("repair.dirty_frac").observe(dirty_frac)


def dirty_mask(n_nodes: int, touched: np.ndarray) -> np.ndarray:
    """(n_nodes,) bool host vector flagging the delta endpoints — the seed
    of the repair frontier (`EdgeDelta.touched()`, already in plan ids)."""
    mask = np.zeros(n_nodes, dtype=bool)
    if touched.size:
        mask[touched] = True
    return mask


def _covered(config, g: Graph, tiled: BlockTiledGraph, in_mis0) -> jnp.ndarray:
    """(n_nodes,) bool — which vertices the seed set dominates (A·S > 0),
    computed on the CONFIGURED engine's own phase-② substrate: the Pallas
    kernel for the `*_pallas` engines (packed tiles unpack in VMEM, never
    in HBM — the same discipline Guard 3 enforces on the rest of the delta
    path), the segment ops for the CC baseline (no tiles touched), the jnp
    oracle for `tiled_ref` and custom engines.  Counts are exact integers
    in every substrate, so the warm state is engine-independent."""
    n = g.n_nodes
    engine = get_engine(config.backend)
    if isinstance(engine, SegmentEngine):
        from repro.core.spmv import neighbor_any_segment

        return neighbor_any_segment(g, in_mis0[:n])
    if isinstance(engine, TiledPallasEngine):   # incl. the fused subclass
        from repro.kernels.ops import tc_spmv

        rhs = jnp.zeros((tiled.n_padded, config.lanes), dtype=jnp.float32)
        rhs = rhs.at[:, 0].set(pack_vertex_vector(
            in_mis0.astype(jnp.float32), tiled
        ))
        return tc_spmv(tiled, rhs, skip_dma=config.skip_dma)[:n, 0] > 0
    rhs = pack_vertex_vector(in_mis0.astype(jnp.float32), tiled)[:, None]
    return tile_spmv(
        tiled.tiles, tiled.tile_rows, tiled.tile_cols, rhs,
        tiled.n_block_rows, tiled.tile_size,
    )[:n, 0] > 0


def _covered_bits(config, engine, tiled: BlockTiledGraph, in_mis_words) -> jnp.ndarray:
    """(nbc, W) uint32 — the packed form of `_covered`: hit words of the
    seed-set SpMV, on the engine's own bitwise phase-② substrate.  Only
    tile-schedule engines reach here (`resolve_frontier` never says bitwise
    for the segment engine)."""
    if isinstance(engine, TiledPallasEngine):   # incl. the fused subclass
        from repro.kernels.ops import tc_spmv_bits

        return tc_spmv_bits(
            tiled, in_mis_words,
            tiles_words=tiles_as_words(tiled.tiles, tiled.tile_size),
            skip_dma=config.skip_dma,
        )
    return tile_spmv_bits(
        tiles_as_words(tiled.tiles, tiled.tile_size),
        tiled.tile_rows, tiled.tile_cols, in_mis_words,
        tiled.n_block_rows, tiled.tile_size,
    )


def warm_state(
    g: Graph,
    tiled: BlockTiledGraph,
    config,
    prior_in_mis: jnp.ndarray,   # (n_nodes,) bool, plan ids, valid pre-delta MIS
    dirty: jnp.ndarray,          # (n_nodes,) bool — delta endpoints
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(alive₀, in_mis₀) for the warm re-entry.

    Dense runs get (n_nodes,) bool vectors; bitwise runs (the resolved
    frontier of this config × storage — same policy `_setup` applies) get
    (nbc, W) uint32 word pairs that `_tc_mis_impl` accepts pre-packed, so
    the warm state never round-trips through a dense frontier on its way
    into the packed round loop.

    Pure jnp/Pallas over the PATCHED representation, so the Solver jits it
    together with the convergence loop — warm-start construction costs one
    SpMV (`_covered`/`_covered_bits`, on the configured engine's substrate)
    inside the same compiled program.
    """
    n = tiled.n_nodes
    in_mis0 = prior_in_mis[:n].astype(bool) & ~dirty[:n].astype(bool)
    engine = get_engine(config.backend)
    if resolve_frontier(config, engine, storage=tiled.storage) == "bitwise":
        T = tiled.tile_size
        in_mis_w = pack_frontier_words(pack_vertex_vector(in_mis0, tiled), T)
        hit_w = _covered_bits(config, engine, tiled, in_mis_w)
        # ~in_mis_w/~hit_w set the PADDING bits too — mask with the real-
        # vertex words or dead padding slots would wake up as alive.
        real_w = pack_frontier_words(jnp.arange(tiled.n_padded) < n, T)
        alive_w = real_w & ~in_mis_w & ~hit_w
        return alive_w, in_mis_w
    alive0 = ~in_mis0 & ~_covered(config, g, tiled, in_mis0)
    return alive0, in_mis0


def repair_mis(
    g: Graph,                    # the PATCHED graph (plan ids)
    tiled: BlockTiledGraph,      # its patched tiling
    key: jax.Array,
    config,                      # SolveOptions (or any engine cfg bundle)
    prior_in_mis: jnp.ndarray,   # (n_nodes,) bool — pre-delta solution
    dirty: jnp.ndarray,          # (n_nodes,) bool — delta endpoints
    *,
    priorities: Optional[Priorities] = None,
) -> MISResult:
    """Warm-started solve of the mutated graph on the configured engine.

    `prior_in_mis` must be a valid MIS of the PRE-delta graph (the Solver
    passes its own last result); the repaired result is then a valid MIS of
    the patched graph for every registered engine and either storage.
    Priorities default to the same construction a cold solve of the patched
    graph would use (same heuristic, same key, the NEW degree vector), so
    an empty delta repairs to exactly the cold answer.  Jit-compatible with
    `config` static — the Solver wraps this whole call in one `jax.jit`.

    With `config.telemetry` the return is `_tc_mis_impl`'s `(result,
    telemetry_buffer)` pair — the round buffer passes through this seam
    untouched, so repaired solves carry per-round series exactly like cold
    ones (the warm loop's row 0 is the first REPAIR round).
    """
    alive0, in_mis0 = warm_state(g, tiled, config, prior_in_mis, dirty)
    return _tc_mis_impl(
        g, tiled, key, config,
        priorities=priorities, alive0=alive0, in_mis0=in_mis0,
    )

"""Drift observability for long-horizon mutation streams (DESIGN.md §17).

PR 5 froze the RCM permutation at epoch 0, so tile locality decays under
sustained churn — BENCH_dyngraph already shows repair losing to cold at
1-5% deltas.  The ROADMAP's re-anchoring item needs a *signal* before a
policy can exist; this module is that signal.  Three gauges, all recorded
at the eager patch seam (`api.plan.patch_plan` — the one funnel every
actual patch event passes through, cached hits excluded so an epoch is
counted exactly once):

* ``dyngraph.touched_tiles`` (histogram) + ``dyngraph.touched_frac`` —
  distinct tiles a delta's half-edges land in: the touched-tiles-per-delta
  trend.  Rising trend at fixed delta size = edges spreading across the
  stale tiling.
* ``dyngraph.locality_decay`` — 1 − occupancy/occupancy₀, where occupancy
  is stored-tile density ``2·E / (n_tiles · T²)`` and occupancy₀ the same
  at the epoch-0 build.  0 at epoch 0; grows toward 1 as the same edges
  smear over ever more tiles (each tile ever emptier); negative when
  mutation *densifies* the tiling (also informative).
* ``dyngraph.dirty_frac`` — fraction of vertices a delta dirties (the
  drift twin of ``repair.dirty_frac``, which records what the *repair*
  decision saw; this one is recorded whether or not a repair follows).

Import-light by design (numpy + the metrics registry): `api.plan` calls
in here, so any jax / core import would re-create the layering cycle the
lazy dyngraph imports in `patch_plan` exist to avoid.  Never on the jitted
hot path — the eager-only metrics contract (DESIGN.md §14) holds.
"""
from __future__ import annotations

import numpy as np

from repro.obs import metrics as obs_metrics


def tile_occupancy(n_edges: int, n_tiles: int, tile_size: int) -> float:
    """Mean stored-tile density: half-edge cells over stored cell capacity.

    Each undirected edge occupies two cells ((u,v) and (v,u)), hence the
    2·E numerator.  Real tiles only — padding tiles are capacity the
    engine skips, not capacity the graph wastes.
    """
    cap = max(int(n_tiles), 1) * int(tile_size) * int(tile_size)
    return 2.0 * max(int(n_edges), 0) / cap


def touched_tile_count(delta, tile_size: int, n_block_cols: int) -> int:
    """Distinct tiles the delta's half-edges land in (add and remove both
    count — a remove dirties its tile's words exactly like an add)."""
    T = int(tile_size)
    nbc = np.int64(max(int(n_block_cols), 1))
    keys = []
    for pairs in (delta.add, delta.remove):
        p = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        if not p.shape[0]:
            continue
        u = np.concatenate([p[:, 0], p[:, 1]])
        v = np.concatenate([p[:, 1], p[:, 0]])
        keys.append((u // T) * nbc + (v // T))
    if not keys:
        return 0
    return int(np.unique(np.concatenate(keys)).shape[0])


def dirty_vertex_frac(delta, n_nodes: int) -> float:
    """Fraction of vertices that are an endpoint of some delta edge."""
    both = np.concatenate([
        np.asarray(delta.add, dtype=np.int64).reshape(-1),
        np.asarray(delta.remove, dtype=np.int64).reshape(-1),
    ])
    if not both.shape[0]:
        return 0.0
    return float(np.unique(both).shape[0]) / max(int(n_nodes), 1)


def note_drift(
    *,
    epoch: int,
    touched_tiles: int,
    n_tiles: int,
    dirty_frac: float,
    occupancy: float,
    occupancy0: float,
) -> None:
    """Record one patch event's drift metrics into the process registry.

    Eager-only (never under a jit trace); called once per *applied* delta
    by `api.plan.patch_plan` — plan-cache mem/disk hits replay a patch
    that already happened and must NOT re-record.
    """
    reg = obs_metrics.REGISTRY
    reg.counter("dyngraph.epochs").inc()
    reg.gauge("dyngraph.epoch").set(epoch)
    reg.histogram("dyngraph.touched_tiles").observe(touched_tiles)
    reg.gauge("dyngraph.touched_frac").set(
        touched_tiles / max(int(n_tiles), 1)
    )
    reg.gauge("dyngraph.dirty_frac").set(dirty_frac)
    reg.gauge("dyngraph.occupancy").set(occupancy)
    decay = 1.0 - occupancy / occupancy0 if occupancy0 > 0 else 0.0
    reg.gauge("dyngraph.locality_decay").set(decay)

"""Streaming graph ingestion: chunked edge readers + the delta file format.

`serve_mis.io.load_graph` reads whole files with `readlines()` — fine for
benchmark fixtures, hostile at serving scale, where a SNAP edge list runs
to gigabytes and a python list of its lines costs ~10× the file in host
RAM.  This module is the bounded-memory ingestion layer over the SAME
line-level parsers: `serve_mis.io` owns one chunked generator per format
(`iter_*_chunks` — single-sited format contract, identical
`GraphParseError`s), and `iter_edges` here adds the file layer — open,
content-sniff the format (`detect_format`, so sniffing beats extensions in
streams too), dispatch, and yield bounded numpy chunks.
`load_graph_stream` folds the chunks straight into `from_edges`, producing
a `Graph` bit-identical to the `load_graph` of the same file — same
canonicalisation, same `graph_content_key`, so streamed graphs hit the
same plan-cache entries.

The delta side of ingestion is `load_delta`: a line-oriented mutation file

    + u v      add undirected edge (u, v)      (bare "u v" lines mean add)
    - u v      remove undirected edge (u, v)
    # ...      comment (as is %)

parsed into a canonical `EdgeDelta` — the wire format of the serve CLI's
`update` verb (`python -m repro.serve_mis`, DESIGN.md §12).
"""
from __future__ import annotations

import itertools
from typing import Iterable, Iterator, List, Optional

from repro.dyngraph.delta import EdgeDelta
from repro.graphs.graph import Graph, from_edges
from repro.serve_mis.io import (
    CHUNKERS,
    DEFAULT_CHUNK_EDGES,
    Chunk,
    GraphParseError,
    _split_ints,
    collect_chunks,
    detect_format,
    resolve_n_nodes,
)


def iter_edges(
    path: str,
    *,
    fmt: Optional[str] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    info: Optional[dict] = None,
) -> Iterator[Chunk]:
    """Stream a graph file as 0-indexed `(src, dst)` int64 chunk pairs.

    Peak memory is one chunk (`chunk_edges` pairs), not the file.  `info`
    (optional dict) receives `fmt` — the detected format — and
    `n_declared`, the vertex count the file itself declares (MatrixMarket
    dims, the DIMACS `p` line; absent for edge lists) once the stream
    reaches the declaring line.  Empty chunks are dropped; whole-file
    invariants (entry-count promises, a missing `p` line) raise at EOF,
    per the shared parser contract.
    """
    if info is None:
        info = {}
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        first = f.readline()
        if fmt is None:
            fmt = detect_format(path, first)
        if fmt not in CHUNKERS:
            raise ValueError(
                f"unknown graph format {fmt!r}; options {sorted(CHUNKERS)}"
            )
        info["fmt"] = fmt
        lines = itertools.chain([first], f) if first else iter(())
        for src, dst in CHUNKERS[fmt](lines, chunk_edges, info):
            if src.size:
                yield src, dst


def load_graph_stream(
    path: str,
    *,
    fmt: Optional[str] = None,
    n_nodes: Optional[int] = None,
    pad_to: Optional[int] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
) -> Graph:
    """Chunked twin of `serve_mis.io.load_graph` — same Graph, same content
    hash, without ever holding the file's line list.

    The accumulated edge arrays still materialise (that is the graph), but
    as packed int64 — the ~10× python-string overhead of `readlines()` is
    gone, which is the term that breaks multi-GB SNAP ingestion.
    """
    info: dict = {}
    s, d, max_id = collect_chunks(
        iter_edges(path, fmt=fmt, chunk_edges=chunk_edges, info=info)
    )
    n = resolve_n_nodes(info["fmt"], max_id, info.get("n_declared"), n_nodes)
    return from_edges(s, d, n, pad_to=pad_to)


# --------------------------------------------------------------------------
# delta files (the serve CLI's `update` verb payload)
# --------------------------------------------------------------------------


def parse_delta(lines: Iterable[str]) -> EdgeDelta:
    """`+ u v` / `- u v` lines → canonical `EdgeDelta` (bare pairs = add)."""
    add_s: List[int] = []
    add_d: List[int] = []
    rem_s: List[int] = []
    rem_d: List[int] = []
    for lineno, raw in enumerate(lines, start=1):
        line = raw.strip()
        if not line or line.startswith(("#", "%")):
            continue
        if line[0] in "+-":
            op, body = line[0], line[1:]
        else:
            op, body = "+", line
        u, v = _split_ints(body, lineno, 2)
        if u < 0 or v < 0:
            raise GraphParseError(f"line {lineno}: negative vertex id in {line!r}")
        (add_s if op == "+" else rem_s).append(u)
        (add_d if op == "+" else rem_d).append(v)
    return EdgeDelta.make(add_s, add_d, rem_s, rem_d)


def load_delta(path: str) -> EdgeDelta:
    """Parse a delta file (see `parse_delta` for the line format)."""
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        return parse_delta(f)

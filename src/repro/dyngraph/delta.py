"""`EdgeDelta` — a canonical, content-hashed batch of edge mutations.

The unit of graph change the whole dyngraph subsystem speaks (DESIGN.md
§12): a set of undirected edges to add and a set to remove, canonicalised
exactly the way `graphs.graph.from_edges` canonicalises a graph — self
loops dropped, duplicates merged, endpoints ordered (lo, hi), pairs sorted
— so two deltas describing the same mutation hash identically whatever
order their edges arrived in.

Semantics are STRICT set operations against the graph a delta is applied
to: every `add` edge must be absent and every `remove` edge present
(`retile.apply_graph_delta` raises otherwise).  Strictness is what makes
`inverse()` a real inverse — `apply(apply(g, d), d.inverse()) == g`
bit-exactly, at both the edge-list and the tile level (the property test in
tests/test_dyngraph.py) — and what keeps the delta-chained plan-cache keys
honest: a key names one concrete graph state, never "this edge, maybe".

`content_key` is the sha256 the epoch-suffixed plan keys chain over
(`repro.api.plan.delta_cache_key`); it covers the canonical pairs only, so
it is independent of input edge order, direction and duplication.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

from repro.graphs.graph import Graph


def _canonical_pairs(src, dst) -> np.ndarray:
    """(k,) + (k,) endpoint arrays → (m, 2) int64 canonical (lo, hi) pairs:
    self loops dropped, deduped, sorted lexicographically."""
    src = np.asarray(src, dtype=np.int64).reshape(-1)
    dst = np.asarray(dst, dtype=np.int64).reshape(-1)
    if src.shape != dst.shape:
        raise ValueError(f"endpoint arrays disagree: {src.shape} vs {dst.shape}")
    keep = src != dst
    lo = np.minimum(src[keep], dst[keep])
    hi = np.maximum(src[keep], dst[keep])
    pairs = np.unique(np.stack([lo, hi], axis=1), axis=0)
    return pairs.reshape(-1, 2)


def _pair_keys(pairs: np.ndarray, n: int) -> np.ndarray:
    """Scalar int64 key per (lo, hi) pair — the set-membership currency."""
    return pairs[:, 0] * np.int64(n) + pairs[:, 1]


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """An immutable edge-mutation batch in canonical form.

    Build through :meth:`make` (which canonicalises); the raw constructor
    trusts its inputs and is for internal use (`inverse`, tests that
    already hold canonical arrays).

    Attributes:
      add:    (n_add, 2) int64 — canonical (lo, hi) pairs to insert.
      remove: (n_remove, 2) int64 — canonical pairs to delete.
    """
    add: np.ndarray
    remove: np.ndarray

    @classmethod
    def make(cls, add_src=(), add_dst=(), rem_src=(), rem_dst=()) -> "EdgeDelta":
        """Canonicalise raw endpoint arrays into a delta.

        An edge appearing in BOTH sets is rejected — "add then remove" (or
        the reverse) has no order-free meaning inside one atomic batch, and
        silently picking one would break the inverse property.
        """
        add = _canonical_pairs(add_src, add_dst)
        rem = _canonical_pairs(rem_src, rem_dst)
        if add.size and rem.size:
            n = int(max(add.max(), rem.max())) + 1
            overlap = np.intersect1d(_pair_keys(add, n), _pair_keys(rem, n))
            if overlap.size:
                raise ValueError(
                    f"{overlap.size} edge(s) appear in both add and remove — "
                    f"a delta is one atomic set mutation, split it instead"
                )
        return cls(add=add, remove=rem)

    @property
    def n_add(self) -> int:
        return int(self.add.shape[0])

    @property
    def n_remove(self) -> int:
        return int(self.remove.shape[0])

    @property
    def is_empty(self) -> bool:
        return self.n_add == 0 and self.n_remove == 0

    @property
    def content_key(self) -> str:
        """sha256 over the canonical pairs — the hash the epoch-suffixed
        plan-cache keys chain over (`repro.api.plan.delta_cache_key`)."""
        h = hashlib.sha256()
        h.update(f"tcmis-edgedelta|{self.n_add}|{self.n_remove}".encode())
        h.update(self.add.astype(np.int64).tobytes())
        h.update(self.remove.astype(np.int64).tobytes())
        return h.hexdigest()

    def inverse(self) -> "EdgeDelta":
        """The undo delta: applying `d` then `d.inverse()` restores the
        graph — and its tiling — bit-exactly (strict semantics guarantee
        the inverse's adds are absent and removes present)."""
        return EdgeDelta(add=self.remove, remove=self.add)

    def touched(self) -> np.ndarray:
        """Sorted unique vertex ids incident to any delta edge — the seed
        of the dirty frontier the MIS repair resets (repair.warm_state)."""
        return np.unique(np.concatenate([
            self.add.reshape(-1), self.remove.reshape(-1),
        ])).astype(np.int64) if not self.is_empty else np.zeros(0, np.int64)

    def mapped(self, mapping: np.ndarray) -> "EdgeDelta":
        """Relabel endpoints through `mapping[old_id] = new_id` and
        re-canonicalise (a permutation may flip (lo, hi) order) — how
        RCM-reordered plans take original-id deltas (`Plan.apply_delta`)."""
        mapping = np.asarray(mapping)
        return EdgeDelta.make(
            mapping[self.add[:, 0]], mapping[self.add[:, 1]],
            mapping[self.remove[:, 0]], mapping[self.remove[:, 1]],
        )

    def check_bounds(self, n_nodes: int) -> None:
        """Deltas never grow the vertex set — a graph's identity (and every
        static shape compiled against it) is its vertex count; growing is a
        new graph, not a delta."""
        hi = -1
        for pairs in (self.add, self.remove):
            if pairs.size:
                hi = max(hi, int(pairs.max()))
        if hi >= n_nodes:
            raise ValueError(
                f"delta references vertex {hi} but the graph has "
                f"{n_nodes} vertices — deltas cannot grow the vertex set"
            )


def random_delta(
    g: Graph,
    n_add: int = 0,
    n_remove: int = 0,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> EdgeDelta:
    """Sample a strict-valid delta for `g`: removals drawn from existing
    edges, additions from non-edges (rejection-sampled).  The generator
    behind the example, the benchmark's delta stream, and the round-trip
    property test — by construction `apply_graph_delta(g, d)` succeeds and
    `d.inverse()` restores `g`.
    """
    rng = np.random.default_rng(seed) if rng is None else rng
    n = g.n_nodes
    s = np.asarray(g.senders)[: g.n_edges].astype(np.int64)
    r = np.asarray(g.receivers)[: g.n_edges].astype(np.int64)
    und = np.unique(np.stack(
        [np.minimum(s, r), np.maximum(s, r)], axis=1), axis=0)
    existing = set(_pair_keys(und, n).tolist()) if und.size else set()

    n_remove = min(int(n_remove), und.shape[0])
    rem = und[rng.choice(und.shape[0], size=n_remove, replace=False)] \
        if n_remove else np.zeros((0, 2), np.int64)

    adds: list = []
    picked = set()
    # rejection sampling; bail out gracefully on near-complete graphs
    max_tries = max(int(n_add), 1) * 64
    while len(adds) < int(n_add) and max_tries > 0 and n >= 2:
        max_tries -= 1
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u == v:
            continue
        lo, hi = min(u, v), max(u, v)
        k = lo * n + hi
        if k in existing or k in picked:
            continue
        picked.add(k)
        adds.append((lo, hi))
    add = np.asarray(adds, np.int64).reshape(-1, 2)
    return EdgeDelta.make(add[:, 0], add[:, 1], rem[:, 0], rem[:, 1])

"""repro.dyngraph — dynamic graphs: streaming ingestion, deltas, MIS repair.

The subsystem that lets a SERVED graph mutate without paying the static
pipeline's full price (DESIGN.md §12):

  stream    chunked edge readers over SNAP/.mtx/DIMACS (`iter_edges`,
            `load_graph_stream`) — ingestion without the whole-file line
            list — plus the `+/- u v` delta file format (`load_delta`)
  delta     `EdgeDelta`: canonical, content-hashed add/remove batches with
            a true `inverse()` (strict set semantics)
  retile    `apply_delta` / `apply_graph_delta`: tile-local repacking —
            word-level bit edits on packed tiles, byte edits on int8 —
            bit-exact with a from-scratch rebuild of the mutated graph
  repair    warm-started round-engine re-entry: seed the prior solution,
            reset only the dirty frontier, converge in a handful of rounds
  drift     per-epoch churn observability (DESIGN.md §17): touched-tiles,
            dirty fraction, tile-locality decay vs the epoch-0 build —
            the signal the ROADMAP's re-anchoring policy will gate on

Front-door plumbing: `Plan.apply_delta` (epoch-suffixed cache keys, stale
pre-delta entries evicted), `SolveOptions.repair`, `Solver.update`, and the
serve_mis `update` service op / CLI verb.
"""
from repro.dyngraph.delta import EdgeDelta, random_delta
from repro.dyngraph.drift import (
    dirty_vertex_frac,
    note_drift,
    tile_occupancy,
    touched_tile_count,
)
from repro.dyngraph.repair import dirty_mask, repair_mis, warm_state
from repro.dyngraph.retile import apply_delta, apply_graph_delta
from repro.dyngraph.stream import (
    iter_edges,
    load_delta,
    load_graph_stream,
    parse_delta,
)

__all__ = [
    "EdgeDelta", "random_delta",
    "apply_delta", "apply_graph_delta",
    "dirty_mask", "repair_mis", "warm_state",
    "dirty_vertex_frac", "note_drift", "tile_occupancy", "touched_tile_count",
    "iter_edges", "load_delta", "load_graph_stream", "parse_delta",
]

"""Tile-local retiling: apply an `EdgeDelta` without rebuilding the tiling.

The BSR build (`core.tiling.build_block_tiles`) scatters every half-edge of
the graph; at serving scale that full rebuild — not the solve — is the cost
of a mutating graph.  But a delta only touches the tiles its endpoints land
in: `apply_delta` edits exactly those, leaving every other tile's bytes (and
the device arrays behind them, on the no-structural-change fast path)
untouched.

Per storage format (DESIGN.md §11):

  int8      byte edits — `tiles[t, u%T, v%T] = 0|1`.
  bitpack   word-level bit edits on the packed uint32 words — OR in
            `1 << bit` to add, AND with the complement to remove.  The
            packed tiles are never densified: the delta path obeys the same
            packed-words-only discipline as the kernels (tools/ci_guards.py
            guards this module too).

Structural changes (an add landing in a block the tiling has no tile for,
or a remove draining a tile's last edge) insert/drop tiles in the row-major
tile list and recompute `row_starts` — an O(n_tiles) index shuffle, still
free of the O(E) edge scatter.  The result is BIT-EXACT with a from-scratch
`build_block_tiles` of the mutated graph — padding convention included —
which is both the correctness oracle of the test suite and what lets
patched plans share cache/bucket machinery with built ones.

`apply_graph_delta` is the edge-list twin: the mutated `Graph` re-enters
`from_edges` canonicalisation, so a patched graph is indistinguishable —
content hash included — from the same graph loaded fresh.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.tiling import (
    BlockTiledGraph,
    packed_words,
    padded_tile_count,
    partition_tiles,
)
from repro.dyngraph.delta import EdgeDelta, _pair_keys
from repro.graphs.graph import Graph, from_edges

_BITS = 32


def _half_edges(pairs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(m, 2) canonical pairs → both directed half-edges (2m,) + (2m,)."""
    lo, hi = pairs[:, 0], pairs[:, 1]
    return np.concatenate([lo, hi]), np.concatenate([hi, lo])


def apply_graph_delta(g: Graph, delta: EdgeDelta) -> Graph:
    """Mutate the edge list: strict set semantics, canonical result.

    Every `remove` edge must exist and every `add` edge must not — the
    strictness `EdgeDelta.inverse()` relies on.  The result goes back
    through `from_edges`, so it is bit-identical (edge order, padding,
    `graph_content_key`) to loading the mutated graph fresh.
    """
    delta.check_bounds(g.n_nodes)
    if delta.is_empty:
        return g
    n = g.n_nodes
    s = np.asarray(g.senders)[: g.n_edges].astype(np.int64)
    r = np.asarray(g.receivers)[: g.n_edges].astype(np.int64)
    und = np.unique(np.stack([np.minimum(s, r), np.maximum(s, r)], axis=1),
                    axis=0).reshape(-1, 2)
    keys = _pair_keys(und, n)

    rem_keys = _pair_keys(delta.remove, n)
    missing = ~np.isin(rem_keys, keys)
    if missing.any():
        u, v = delta.remove[missing.argmax()]
        raise ValueError(
            f"delta removes {int(missing.sum())} edge(s) not in the graph "
            f"(first: ({int(u)}, {int(v)})) — deltas are strict set mutations"
        )
    add_keys = _pair_keys(delta.add, n)
    present = np.isin(add_keys, keys)
    if present.any():
        u, v = delta.add[present.argmax()]
        raise ValueError(
            f"delta adds {int(present.sum())} edge(s) already in the graph "
            f"(first: ({int(u)}, {int(v)})) — deltas are strict set mutations"
        )

    kept = und[~np.isin(keys, rem_keys)]
    new = np.concatenate([kept, delta.add], axis=0)
    return from_edges(new[:, 0], new[:, 1], n)


def _edit_tiles(
    tiles: np.ndarray,
    tidx: np.ndarray,    # (k,) tile index per half-edge
    u: np.ndarray,       # (k,) row vertex ids
    v: np.ndarray,       # (k,) column vertex ids
    T: int,
    *,
    set_bit: bool,
) -> None:
    """In-place cell edits in either storage format (detected by dtype)."""
    rloc, cloc = u % T, v % T
    if tiles.dtype == np.uint32:   # bitpack: word-level bit edits
        word, bit = cloc // _BITS, (cloc % _BITS).astype(np.uint32)
        if set_bit:
            np.bitwise_or.at(tiles, (tidx, rloc, word), np.uint32(1) << bit)
        else:
            np.bitwise_and.at(tiles, (tidx, rloc, word), ~(np.uint32(1) << bit))
    else:
        tiles[tidx, rloc, cloc] = 1 if set_bit else 0


def _repartition(
    old: BlockTiledGraph, out: BlockTiledGraph
) -> BlockTiledGraph:
    """Hybrid reclassification after a tile edit (DESIGN.md §16): a delta
    can push a tile across the nnz threshold in either direction, and the
    compacted dense partition holds COPIES of the edited tiles — so a
    partitioned input rebuilds its partition, at the same threshold, over
    the mutated tile list.  Deterministic (`partition_tiles`), hence still
    bit-exact with partitioning a from-scratch rebuild.  Plan-level 'auto'
    gate re-evaluation is the caller's concern (`api.plan.patch_plan`)."""
    if old.partition is None:
        return out
    return dataclasses.replace(
        out, partition=partition_tiles(out, old.partition.threshold)
    )


def apply_delta(tiled: BlockTiledGraph, delta: EdgeDelta) -> BlockTiledGraph:
    """Repack only the touched tiles of a `BlockTiledGraph`.

    Fast path — the delta lands entirely in existing tiles and drains none:
    the tile payload is edited in place on a host copy and `tile_rows` /
    `tile_cols` / `row_starts` are REUSED (same device arrays, no re-upload).
    Structural path — tiles are inserted (new block touched) and/or dropped
    (last edge removed) in row-major order and `row_starts` is recomputed
    from the new tile rows.  Either way the result equals
    `build_block_tiles(apply_graph_delta(g, delta))` bit-for-bit.

    Trusts its delta (bounds + strictness are `apply_graph_delta`'s checks,
    run by `Plan.apply_delta` on the same batch); a remove aimed at an
    absent edge is a silent no-op bit-clear here, so callers composing the
    two must apply the SAME canonical delta to both representations.
    """
    delta.check_bounds(tiled.n_nodes)
    if delta.is_empty:
        return tiled
    T = tiled.tile_size
    nbc = tiled.n_block_cols
    nt = tiled.n_tiles

    rows_np = np.asarray(tiled.tile_rows)[:nt]
    cols_np = np.asarray(tiled.tile_cols)[:nt]
    tile_keys = rows_np.astype(np.int64) * nbc + cols_np   # sorted (row-major)

    add_u, add_v = _half_edges(delta.add)
    rem_u, rem_v = _half_edges(delta.remove)
    add_keys = (add_u // T) * np.int64(nbc) + (add_v // T)
    rem_keys = (rem_u // T) * np.int64(nbc) + (rem_v // T)

    new_keys = np.setdiff1d(np.unique(add_keys), tile_keys)
    if new_keys.size == 0:
        # ---- fast path candidate: all edits hit existing tiles ----------
        stored = np.array(tiled.tiles)                     # host copy, pad incl.
        ridx = np.searchsorted(tile_keys, rem_keys)        # (may be empty)
        if rem_keys.size:
            _edit_tiles(stored, ridx, rem_u, rem_v, T, set_bit=False)
        if add_keys.size:
            aidx = np.searchsorted(tile_keys, add_keys)
            _edit_tiles(stored, aidx, add_u, add_v, T, set_bit=True)
        # drain check over exactly the tiles the removes edited
        touched = np.unique(ridx)
        drained = touched[~stored[touched].any(axis=(1, 2))] \
            if touched.size else touched
        if drained.size == 0:
            return _repartition(
                tiled, dataclasses.replace(tiled, tiles=jnp.asarray(stored))
            )
        keep = np.ones(nt, bool)
        keep[drained] = False
        return _repartition(
            tiled, _rebuild_index(tiled, stored[:nt][keep], tile_keys[keep])
        )

    # ---- structural path: merge new (zero) tiles into the sorted list ---
    merged_keys = np.union1d(tile_keys, new_keys)
    n_merged = int(merged_keys.shape[0])
    if tiled.storage == "bitpack":
        shape = (n_merged, T, packed_words(T))
        merged = np.zeros(shape, np.uint32)
    else:
        merged = np.zeros((n_merged, T, T), np.int8)
    old_pos = np.searchsorted(merged_keys, tile_keys)
    merged[old_pos] = np.asarray(tiled.tiles)[:nt]
    rem_idx = np.searchsorted(merged_keys, rem_keys)       # (may be empty)
    if rem_keys.size:
        _edit_tiles(merged, rem_idx, rem_u, rem_v, T, set_bit=False)
    _edit_tiles(merged, np.searchsorted(merged_keys, add_keys),
                add_u, add_v, T, set_bit=True)
    # drain check over exactly the tiles the removes edited
    touched = np.unique(rem_idx)
    drained = touched[~merged[touched].any(axis=(1, 2))] \
        if touched.size else touched
    if drained.size:
        keep = np.ones(n_merged, bool)
        keep[drained] = False
        merged, merged_keys = merged[keep], merged_keys[keep]
    return _repartition(tiled, _rebuild_index(tiled, merged, merged_keys))


def _rebuild_index(
    tiled: BlockTiledGraph, tiles: np.ndarray, keys: np.ndarray
) -> BlockTiledGraph:
    """Re-derive rows/cols/row_starts/padding from a sorted real-tile list —
    the O(n_tiles) tail of the structural path (never an edge scatter)."""
    nbc = tiled.n_block_cols
    n_real = int(tiles.shape[0])
    rows = (keys // nbc).astype(np.int32)
    cols = (keys % nbc).astype(np.int32)
    if n_real == 0:
        # mirror build_block_tiles' empty-graph shape: one zero tile at (0,0)
        tiles = np.zeros((1,) + tiles.shape[1:], tiles.dtype)
        rows = np.zeros(1, np.int32)
        cols = np.zeros(1, np.int32)

    counts = np.bincount(rows[: max(n_real, 1)] if n_real else [],
                         minlength=tiled.n_block_rows)
    row_starts = np.zeros(tiled.n_block_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=row_starts[1:])

    target = padded_tile_count(n_real)
    stored = tiles.shape[0]
    if target > stored:
        last_row = rows[-1] if n_real else np.int32(0)
        tiles = np.concatenate(
            [tiles, np.zeros((target - stored,) + tiles.shape[1:], tiles.dtype)]
        )
        rows = np.concatenate(
            [rows, np.full(target - stored, last_row, np.int32)])
        cols = np.concatenate([cols, np.zeros(target - stored, np.int32)])
    return dataclasses.replace(
        tiled,
        tiles=jnp.asarray(tiles),
        tile_rows=jnp.asarray(rows),
        tile_cols=jnp.asarray(cols),
        row_starts=jnp.asarray(row_starts),
        n_tiles=n_real,
    )
